"""Continuous-batching serve engine (ISSUE 5): slot pool lifetimes, FCFS
scheduling, token-exact parity of continuous batching vs isolated decode
across staggered joins/retirements, the zero-recompile contract, the
seeded sampler, and the planner's serve capacity report."""
from __future__ import annotations

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, plan
from repro.models import transformer
from repro.serve import (Request, Scheduler, ServeEngine, SlotPool,
                         sample_tokens, synthetic_trace)
from repro.serve.trace import TraceRequest
from repro.train.serve_step import build_prefill_step


def _smoke_cfg():
    return configs.smoke_config("llama3-8b")


@pytest.fixture(scope="module")
def llama():
    cfg = _smoke_cfg()
    return cfg, transformer.init_params(cfg, jax.random.PRNGKey(0))


def _isolated_greedy(params, cfg, prompt, n_new, s_max):
    """Single-request reference: batch-1 prefill + scalar-pos decode."""
    logits, aux = transformer.forward(
        params, cfg, {"tokens": jnp.asarray(prompt)[None]},
        build_cache=True, cache_quantized=True)
    cache = transformer.grow_cache(aux["cache"], s_max)
    cache["pos"] = jnp.int32(len(prompt))
    toks = [int(logits[0, -1].argmax(-1))]
    tok = jnp.asarray([toks[-1]], jnp.int32)
    for _ in range(n_new - 1):
        lg, cache = transformer.decode_step(params, cfg, cache, tok,
                                            quantized=True)
        tok = jnp.asarray(lg.argmax(-1), jnp.int32)
        toks.append(int(tok[0]))
    return toks


# ---------------------------------------------------------------------------
class TestSlotPool:
    def test_alloc_free_cycle(self):
        pool = SlotPool(_smoke_cfg(), 3, 32)
        slots = [pool.alloc() for _ in range(3)]
        assert sorted(slots) == [0, 1, 2] and pool.occupancy == 3
        assert pool.alloc() is None            # exhausted, not an error
        pool.free(slots[1])
        assert pool.occupancy == 2 and pool.alloc() == slots[1]
        for s in (slots[0], slots[1], slots[2]):
            pool.free(s)
        assert pool.occupancy == 0 and pool.allocs == pool.frees == 4

    def test_double_free_raises(self):
        pool = SlotPool(_smoke_cfg(), 2, 32)
        s = pool.alloc()
        pool.free(s)
        with pytest.raises(ValueError, match="not live"):
            pool.free(s)

    def test_per_slot_lengths_and_bytes(self):
        cfg = _smoke_cfg()
        pool = SlotPool(cfg, 4, 32)
        assert pool.cache["pos"].shape == (4,)     # occupancy is data
        assert pool.cache["k"].dtype == jnp.int8
        # exact accounting: batch axis is the slot axis on every leaf
        assert pool.bytes_per_slot() * 4 == sum(
            x.size * x.dtype.itemsize
            for k, x in pool.cache.items() if k != "pos")


# ---------------------------------------------------------------------------
def _req(rid, plen=4, gen=4, arrival=0):
    return Request(rid=rid, prompt=np.ones((plen,), np.int32),
                   max_new_tokens=gen, arrival_step=arrival)


class TestScheduler:
    def test_fcfs_order_and_quota(self):
        sch = Scheduler(4, max_prefill_per_step=2)
        for i in range(4):
            sch.submit(_req(i))
        got = sch.pop_admissible(free_slots=4, now_step=0)
        assert [r.rid for r in got] == [0, 1]      # quota caps per step
        got = sch.pop_admissible(free_slots=2, now_step=0)
        assert [r.rid for r in got] == [2, 3]
        assert sch.queue_depth == 0 and sch.resident == 4

    def test_head_of_line_blocks_on_slots_and_arrival(self):
        sch = Scheduler(2, max_prefill_per_step=4)
        sch.submit(_req(0, arrival=5))
        sch.submit(_req(1, arrival=0))            # behind a later arrival
        assert sch.pop_admissible(free_slots=2, now_step=0) == []
        assert sch.pop_admissible(free_slots=0, now_step=5) == []
        assert [r.rid for r in sch.pop_admissible(2, 5)] == [0, 1]

    def test_byte_budget_bounds_residency(self):
        sch = Scheduler(8, bytes_per_slot=100, byte_budget=250,
                        max_prefill_per_step=8)
        for i in range(4):
            sch.submit(_req(i))
        got = sch.pop_admissible(free_slots=8, now_step=0)
        assert len(got) == 2                       # 3 slots would be 300 B
        sch.retire(got[0])
        assert len(sch.pop_admissible(8, 0)) == 1

    def test_retire_accounting(self):
        sch = Scheduler(2)
        sch.submit(_req(0))
        (r,) = sch.pop_admissible(2, 0)
        sch.retire(r)
        assert sch.resident == 0 and not sch.has_work()
        with pytest.raises(ValueError, match="DONE"):
            sch.retire(r)


# ---------------------------------------------------------------------------
class TestSampling:
    def test_greedy_is_argmax(self):
        logits = jnp.asarray(np.random.default_rng(0).normal(size=(5, 33)),
                             jnp.float32)
        got = sample_tokens(logits, temperature=0.0)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(logits.argmax(-1)))

    def test_temperature_needs_key(self):
        with pytest.raises(ValueError, match="PRNG key"):
            sample_tokens(jnp.zeros((1, 8)), temperature=0.5)

    def test_seeded_and_topk_support(self):
        logits = jnp.asarray(np.random.default_rng(1).normal(size=(64, 50)),
                             jnp.float32)
        key = jax.random.PRNGKey(7)
        a = sample_tokens(logits, key, temperature=0.8, top_k=5)
        b = sample_tokens(logits, key, temperature=0.8, top_k=5)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # every sampled token must come from its row's top-5 set
        top5 = np.asarray(jax.lax.top_k(logits, 5)[1])
        assert all(int(a[i]) in top5[i] for i in range(logits.shape[0]))
        # high temperature over the full vocab should leave the argmax
        # sometimes (sanity that it's not greedy in disguise)
        c = sample_tokens(logits, key, temperature=5.0)
        assert (np.asarray(c) != np.asarray(logits.argmax(-1))).any()


# ---------------------------------------------------------------------------
class TestTrace:
    def test_deterministic_and_bounded(self):
        t1 = synthetic_trace(20, seed=5, vocab=100, mean_prompt=8,
                             max_prompt=16, mean_gen=4, max_gen=8)
        t2 = synthetic_trace(20, seed=5, vocab=100, mean_prompt=8,
                             max_prompt=16, mean_gen=4, max_gen=8)
        assert len(t1) == 20
        for a, b in zip(t1, t2):
            assert a.arrival_step == b.arrival_step
            assert a.max_new_tokens == b.max_new_tokens
            np.testing.assert_array_equal(a.prompt, b.prompt)
        steps = [r.arrival_step for r in t1]
        assert steps == sorted(steps)
        assert all(4 <= len(r.prompt) <= 16 and 1 <= r.max_new_tokens <= 8
                   and r.prompt.max() < 100 for r in t1)


# ---------------------------------------------------------------------------
class TestPerSlotDecode:
    """Model-layer contract the engine builds on: vector cache['pos']."""

    def test_vector_pos_matches_scalar(self, llama):
        cfg, params = llama
        tok = jnp.asarray([3, 5], jnp.int32)
        c_s = transformer.init_cache(cfg, 2, 16, quantized=True)
        c_s["pos"] = jnp.int32(4)
        c_v = transformer.init_cache(cfg, 2, 16, quantized=True)
        c_v["pos"] = jnp.asarray([4, 4], jnp.int32)
        lg_s, nc_s = transformer.decode_step(params, cfg, c_s, tok,
                                             quantized=True)
        lg_v, nc_v = transformer.decode_step(params, cfg, c_v, tok,
                                             quantized=True)
        np.testing.assert_allclose(np.asarray(lg_s), np.asarray(lg_v),
                                   atol=1e-5)
        np.testing.assert_array_equal(np.asarray(nc_v["pos"]), [5, 5])

    def test_active_mask_freezes_slots(self, llama):
        cfg, params = llama
        cache = transformer.init_cache(cfg, 3, 16, quantized=True)
        cache["pos"] = jnp.asarray([4, 7, 2], jnp.int32)
        active = jnp.asarray([True, False, True])
        k_before = np.asarray(cache["k"])
        _, nc = transformer.decode_step(params, cfg, cache,
                                        jnp.zeros((3,), jnp.int32),
                                        quantized=True, active=active)
        np.testing.assert_array_equal(np.asarray(nc["pos"]), [5, 7, 3])

    def test_active_without_vector_pos_raises(self, llama):
        cfg, params = llama
        cache = transformer.init_cache(cfg, 2, 16, quantized=True)
        with pytest.raises(ValueError, match="active"):
            transformer.decode_step(params, cfg, cache,
                                    jnp.zeros((2,), jnp.int32),
                                    active=jnp.asarray([True, True]))

    def test_per_slot_needs_kvq_layout(self):
        cfg = configs.smoke_config("mamba2-130m")
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        cache = transformer.init_cache(cfg, 2, 16)
        cache["pos"] = jnp.zeros((2,), jnp.int32)
        with pytest.raises(NotImplementedError, match="kvq"):
            transformer.decode_step(params, cfg, cache,
                                    jnp.zeros((2,), jnp.int32))

    def test_grow_cache(self, llama):
        cfg, _ = llama
        cache = transformer.init_cache(cfg, 2, 8, quantized=True)
        grown = transformer.grow_cache(cache, 32)
        assert grown["k"].shape[3] == 32 and grown["v_scale"].shape[3] == 32
        with pytest.raises(ValueError, match="grow_cache"):
            transformer.grow_cache(grown, 8)


# ---------------------------------------------------------------------------
class TestPrefillPrealloc:
    def test_prefill_emits_final_length_cache(self, llama):
        cfg, params = llama
        prompts = jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab, (2, 12)), jnp.int32)
        step = jax.jit(build_prefill_step(cfg, quantized=True, s_max=40))
        logits, cache = step(params, {"tokens": prompts})
        assert logits.shape == (2, cfg.vocab)
        assert cache["k"].shape[3] == 40 and cache["k_scale"].shape[3] == 40
        assert int(cache["pos"]) == 12
        # the grown tail is zeros — nothing stale can leak into decode
        assert not np.asarray(cache["k"])[:, :, :, 12:].any()


# ---------------------------------------------------------------------------
class TestEngineParity:
    """Continuous-batched greedy == isolated single-request decode,
    token for token, across staggered joins and retirements."""

    def _trace(self, cfg):
        rng = np.random.default_rng(11)
        specs = [(0, 7, 6), (0, 13, 3), (2, 5, 8), (4, 16, 2), (7, 9, 5)]
        return [TraceRequest(arrival_step=a,
                             prompt=rng.integers(0, cfg.vocab, (p,),
                                                 dtype=np.int32),
                             max_new_tokens=g)
                for (a, p, g) in specs]

    def test_tokens_match_isolated(self, llama):
        cfg, params = llama
        trace = self._trace(cfg)
        eng = ServeEngine(params, cfg, max_slots=2, max_len=48,
                          prompt_buckets=(8, 16), seed=0)
        eng.warmup()
        eng.run(trace)
        assert len(eng._requests_done) == len(trace)
        for t in trace:
            req = next(r for r in eng._requests_done
                       if r.prompt_len == len(t.prompt)
                       and r.max_new_tokens == t.max_new_tokens)
            ref = _isolated_greedy(params, cfg, t.prompt,
                                   t.max_new_tokens, 48)
            assert req.tokens == ref, (req.rid, req.tokens, ref)

    def test_interpret_backend_matches_ref(self, llama):
        cfg, params = llama
        trace = self._trace(cfg)[:3]
        toks = {}
        for backend in ("ref", "interpret"):
            eng = ServeEngine(params, cfg, max_slots=2, max_len=48,
                              prompt_buckets=(8, 16), seed=0,
                              kv_backend=backend, kv_splits=2)
            eng.warmup()
            eng.run(trace)
            toks[backend] = sorted(tuple(r.tokens)
                                   for r in eng._requests_done)
        assert toks["ref"] == toks["interpret"]


class TestEngineInvariants:
    def _engine(self, llama, **kw):
        cfg, params = llama
        kw.setdefault("max_slots", 3)
        kw.setdefault("max_len", 48)
        kw.setdefault("prompt_buckets", (8, 16))
        return ServeEngine(params, cfg, **kw)

    def test_no_recompile_after_warmup(self, llama):
        cfg, params = llama
        eng = self._engine(llama)
        baseline = eng.warmup()
        trace = synthetic_trace(9, seed=2, vocab=cfg.vocab, mean_prompt=8,
                                max_prompt=16, mean_gen=6, max_gen=12,
                                arrival_rate=0.8)
        eng.run(trace)
        assert eng.compile_counts() == baseline, \
            "mid-flight join/evict re-jitted a program"

    def test_slot_leak_invariant(self, llama):
        eng = self._engine(llama)
        eng.warmup()
        trace = synthetic_trace(8, seed=4, vocab=_smoke_cfg().vocab,
                                mean_prompt=8, max_prompt=16, mean_gen=5,
                                max_gen=10, arrival_rate=0.6)
        summary = eng.run(trace)
        assert summary["n_done"] == 8
        assert eng.pool.allocs == eng.pool.frees        # every alloc freed
        assert eng.pool.occupancy == 0                  # pool drained
        assert eng.scheduler.resident == 0
        assert summary["total_tokens"] == sum(
            len(r.tokens) for r in eng._requests_done)
        assert 0 < summary["occupancy_mean"] <= 3

    def test_eos_retires_early(self, llama):
        cfg, params = llama
        prompt = np.random.default_rng(3).integers(0, cfg.vocab, (9,),
                                                   dtype=np.int32)
        eng = self._engine(llama)
        eng.warmup()
        eng.run([TraceRequest(0, prompt, 8)])
        (ref,) = eng._requests_done
        assert len(ref.tokens) == 8
        eos = ref.tokens[2]
        eng2 = self._engine(llama, eos_id=eos)
        eng2.warmup()
        eng2.run([TraceRequest(0, prompt, 8)])
        (got,) = eng2._requests_done
        assert got.tokens == ref.tokens[:3]             # stopped AT the eos
        assert eng2.pool.occupancy == 0

    def test_mem_budget_clamps_slots(self, llama):
        cfg, params = llama
        per_slot = SlotPool(cfg, 1, 48).bytes_per_slot()
        eng = self._engine(llama, max_slots=8,
                           mem_budget_bytes=3 * per_slot + 1)
        assert eng.pool.max_slots == 3
        assert eng.capacity_report["max_slots"] == 3
        with pytest.raises(ValueError, match="0 slots"):
            self._engine(llama, mem_budget_bytes=per_slot - 1)

    def test_unsupported_arch_raises(self):
        cfg = configs.smoke_config("mamba2-130m")
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        with pytest.raises(NotImplementedError, match="lockstep"):
            ServeEngine(params, cfg, max_slots=2, max_len=32)

    def test_oversize_request_rejected(self, llama):
        eng = self._engine(llama)
        with pytest.raises(ValueError, match="max_len"):
            eng.submit(np.zeros((16,), np.int32), 64)
        with pytest.raises(ValueError, match="bucket"):
            eng.submit(np.zeros((17,), np.int32), 1)


# ---------------------------------------------------------------------------
class TestServeCapacityReport:
    def test_matches_pool_accounting(self):
        cfg = _smoke_cfg()
        rep = plan.serve_capacity_report(cfg, 64, 10 * 2**20)
        assert rep["eligible"]
        assert rep["bytes_per_slot"] == SlotPool(cfg, 1, 64).bytes_per_slot()
        assert rep["max_slots"] == (10 * 2**20) // rep["bytes_per_slot"]
        # full-causal GQA arch: the exact accounting IS the kv_cache_report
        assert rep["bytes_per_slot"] == rep["kv_int8_bytes_per_slot"]

    def test_params_bytes_and_budget(self):
        cfg = _smoke_cfg()
        rep = plan.serve_capacity_report(cfg, 64, 2**20,
                                         params_bytes=2**20)
        assert rep["max_slots"] == 0
        full = plan.serve_capacity_report(cfg, 64, 2**30)
        half = plan.serve_capacity_report(cfg, 32, 2**30)
        assert 0 < full["max_slots"] < half["max_slots"]

    def test_unquantized_slots_cost_more(self):
        cfg = _smoke_cfg()
        q = plan.serve_capacity_report(cfg, 64, 2**30, quantized=True)
        f = plan.serve_capacity_report(cfg, 64, 2**30, quantized=False)
        assert q["max_slots"] > f["max_slots"]


# ---------------------------------------------------------------------------
class TestEngineCLI:
    def test_engine_mode_banner_and_metrics(self):
        env = {**os.environ, "PYTHONPATH": "src", "PYTHONUNBUFFERED": "1",
               "XLA_FLAGS": "", "JAX_PLATFORMS": "cpu"}
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--arch",
             "llama3-8b", "--smoke", "--engine", "--requests", "4",
             "--max-slots", "2", "--max-len", "64", "--mean-prompt", "8",
             "--mean-gen", "4"],
            env=env, capture_output=True, text=True, timeout=480)
        assert out.returncode == 0, out.stdout + out.stderr
        assert "capacity:" in out.stdout
        assert "throughput:" in out.stdout
        assert "ttft:" in out.stdout
        assert "occupancy:" in out.stdout
