"""Paper Algorithms 1-4 + the TPU u32 codec: exactness, capacity limits,
SBS weight compliance — including hypothesis property tests."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fixed-seed fallback (requirements-dev)
    from hypothesis_fallback import given, settings, strategies as st

from repro.core import encoding

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def u8_batches(max_n=6, max_hw=8):
    return st.tuples(
        st.integers(1, max_n), st.integers(1, max_hw), st.integers(1, max_hw),
        st.integers(1, 3), st.integers(0, 2**31 - 1),
    ).map(lambda t: np.random.default_rng(t[4]).integers(
        0, 256, size=t[:4], dtype=np.uint8))


class TestBase256:
    @given(u8_batches(max_n=6))
    def test_roundtrip_exact(self, batch):
        enc = encoding.encode_base256(batch)
        dec = encoding.decode_base256(enc, batch.shape[0])
        np.testing.assert_array_equal(dec, batch)

    def test_capacity_enforced(self):
        batch = np.zeros((7, 2, 2, 1), np.uint8)
        with pytest.raises(ValueError):
            encoding.encode_base256(batch)

    def test_f64_mantissa_limit_is_real(self):
        """Paper claims 16 images in f64; the 53-bit mantissa caps exact
        decode at 6 — this documents why the framework uses u32 packing."""
        rng = np.random.default_rng(1)
        batch = rng.integers(0, 256, (7, 4, 4, 1), np.uint8)
        acc = np.zeros(batch.shape[1:], np.float64)
        for i in range(7):
            acc += batch[i].astype(np.float64) * (256.0 ** i)
        dec = encoding.decode_base256(acc, 7)
        assert not np.array_equal(dec, batch)  # 7th image corrupts


class TestLossless:
    @given(u8_batches(max_n=7))
    def test_roundtrip_exact(self, batch):
        enc, off = encoding.encode_lossless(batch)
        dec = encoding.decode_lossless(enc, off)
        np.testing.assert_array_equal(dec, batch)

    def test_doubles_capacity(self):
        batch = np.full((7, 2, 2, 1), 255, np.uint8)
        enc, off = encoding.encode_lossless(batch)  # 7 > base-256 cap of 6
        np.testing.assert_array_equal(encoding.decode_lossless(enc, off), batch)


class TestU32Codec:
    @given(u8_batches(max_n=4).filter(lambda b: b.shape[0] == 4))
    def test_roundtrip(self, batch):
        packed = encoding.pack_u8_to_u32(batch)
        assert packed.dtype == np.uint32
        np.testing.assert_array_equal(encoding.unpack_u32_to_u8(packed), batch)

    def test_requires_multiple_of_4(self):
        with pytest.raises(ValueError):
            encoding.pack_u8_to_u32(np.zeros((3, 2, 2), np.uint8))

    def test_compression_ratio(self):
        assert encoding.compression_ratio(4, "u32") == 16.0


class TestSBS:
    @given(st.integers(0, 1000), st.integers(2, 6))
    def test_weighted_counts(self, seed, n_classes):
        rng = np.random.default_rng(seed)
        labels = rng.integers(0, n_classes, 400)
        weights = {c: 1.0 + (c == 0) for c in range(n_classes)}  # class 0 2x
        idx = encoding.selective_batch_indices(labels, weights, 32, rng)
        assert len(idx) == 32
        counts = np.bincount(labels[idx], minlength=n_classes)
        total_w = n_classes + 1.0
        expect0 = 32 * 2.0 / total_w
        assert abs(counts[0] - expect0) <= 1.0  # rounding tolerance

    def test_zero_weight_class_excluded(self):
        rng = np.random.default_rng(0)
        labels = np.array([0] * 50 + [1] * 50)
        idx = encoding.selective_batch_indices(labels, {0: 1.0, 1: 0.0}, 10, rng)
        assert (labels[idx] == 0).all()
