"""Sharding rules, collectives, optimizer, compression — distributed layer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed import sharding as shd
from repro.distributed.collectives import sp_decode_attention
from repro.models import transformer
from repro.optim import adamw, compression


def _mesh11():
    from repro.launch.mesh import make_mesh
    return make_mesh((1, 1), ("data", "model"))


class TestParamSpecs:
    @pytest.mark.parametrize("arch", configs.list_archs())
    def test_specs_cover_tree_and_axes_valid(self, arch):
        cfg = configs.get_config(arch)
        sds = jax.eval_shape(
            lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
        specs = shd.param_specs(cfg, sds)
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        flat_p = jax.tree_util.tree_leaves(sds)
        assert len(flat_s) == len(flat_p)
        for spec, leaf in zip(flat_s, flat_p):
            assert len(spec) <= len(leaf.shape)
            # any model-sharded dim must divide by 16 (production TP width)
            for ax, name in zip(range(len(spec)), spec):
                if name == "model":
                    assert leaf.shape[ax] % 16 == 0, (spec, leaf.shape)

    def test_ssm_params_replicated(self):
        cfg = configs.get_config("mamba2-130m")
        sds = jax.eval_shape(
            lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
        specs = shd.param_specs(cfg, sds)
        for s in jax.tree_util.tree_leaves(
                specs["blocks"]["ssm"], is_leaf=lambda x: isinstance(x, P)):
            assert s == P()


class TestAdamW:
    def test_converges_on_quadratic(self):
        cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                                total_steps=200, grad_clip=0)
        params = {"w": jnp.array([5.0, -3.0])}
        state = adamw.init(params)
        for _ in range(150):
            g = {"w": 2 * params["w"]}
            params, state, _ = adamw.update(cfg, g, state, params)
        assert float(jnp.abs(params["w"]).max()) < 0.3

    def test_skip_freezes_state(self):
        cfg = adamw.AdamWConfig(lr=0.1)
        params = {"w": jnp.ones((2,))}
        state = adamw.init(params)
        p2, s2, _ = adamw.update(cfg, {"w": jnp.ones((2,))}, state, params,
                                 skip=jnp.bool_(True))
        np.testing.assert_array_equal(np.asarray(p2["w"]),
                                      np.asarray(params["w"]))
        assert int(s2.count) == 0

    def test_grad_clip(self):
        cfg = adamw.AdamWConfig(lr=1e-3, grad_clip=1.0)
        params = {"w": jnp.ones((4,))}
        _, _, m = adamw.update(cfg, {"w": jnp.full((4,), 100.0)},
                               adamw.init(params), params)
        assert float(m["grad_norm"]) == pytest.approx(200.0)


class TestCompression:
    def test_int8_unbiased_roundtrip(self):
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, (512,)) * 3
        outs = []
        for i in range(50):
            q, s = compression.quantize_int8(x, jax.random.PRNGKey(i))
            outs.append(compression.dequantize_int8(q, s))
        err = np.abs(np.mean(outs, axis=0) - np.asarray(x))
        assert err.max() < 0.05  # stochastic rounding -> unbiased mean

    def test_error_feedback_reduces_bias(self):
        grads = {"w": jnp.linspace(-1, 1, 256)}
        res = None
        recon_sum = jnp.zeros((256,))
        for i in range(20):
            payload, res = compression.compress_with_feedback(
                grads, res, jax.random.PRNGKey(i), codec="int8")
            recon_sum += compression.dequantize_int8(*payload["w"])
        # cumulative reconstruction tracks cumulative true grads
        np.testing.assert_allclose(np.asarray(recon_sum) / 20,
                                   np.asarray(grads["w"]), atol=0.02)

    def test_topk_payload_smaller(self):
        grads = {"w": jnp.ones((1000,))}
        payload, _ = compression.compress_with_feedback(
            grads, None, jax.random.PRNGKey(0), codec="topk", topk_frac=0.01)
        assert compression.payload_bytes(payload) < 1000 * 4 * 0.05


class TestSPDecodeAttention:
    def test_matches_plain_softmax(self):
        mesh = _mesh11()
        b, h, s, d = 2, 4, 64, 16
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(b, h, d)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, h, s, d)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, h, s, d)).astype(np.float32))
        bias = jnp.where(jnp.arange(s)[None] < 40, 0.0, -1e30)
        bias = jnp.broadcast_to(bias, (b, s)).astype(jnp.float32)
        out = sp_decode_attention(q, k, v, bias, mesh, sm_scale=d ** -0.5)
        logits = jnp.einsum("bhd,bhsd->bhs", q, k) * d ** -0.5 + bias[:, None]
        p = jax.nn.softmax(logits, -1)
        ref = jnp.einsum("bhs,bhsd->bhd", p, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)


class TestCacheSpecs:
    def test_decode32k_shards_batch_and_sequence(self):
        cfg = configs.get_config("llama3-8b")
        cache = jax.eval_shape(
            lambda: transformer.init_cache(cfg, 128, 1024, quantized=True))
        from repro.launch.mesh import abstract_mesh
        mesh = abstract_mesh((16, 16), ("data", "model"))
        specs = shd.cache_specs(cfg, cache, mesh)
        assert specs["k"][1] == "data"     # batch over DP
        # llama3 kv=8 heads don't divide model=16 -> sequence over model
        assert specs["k"][3] == "model"

    def test_long500k_shards_sequence(self):
        cfg = configs.get_config("hymba-1.5b")
        cache = jax.eval_shape(
            lambda: transformer.init_cache(cfg, 1, 2048, quantized=True))
        from repro.launch.mesh import abstract_mesh
        mesh = abstract_mesh((16, 16), ("data", "model"))
        specs = shd.cache_specs(cfg, cache, mesh)
        assert specs["k"][3] == ("data", "model")  # sequence sharded
