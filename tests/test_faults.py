"""Fault tolerance (ISSUE 7): scheduler terminal states, bounded queue +
deadlines, pool quarantine/audit, the fused decode health sentinel, seeded
fault injection with deterministic replay, graceful drain, stalled
summaries, and the training guards (NaN-skip + rollback).

The acceptance scenario: under a seeded FaultPlan (NaN logits, corrupted
cache row, dropped scatter, cancel/deadline storms) the engine drains
with zero slot leaks, every SURVIVING request's tokens exactly match a
fault-free greedy run, summary counts reconcile with the plan, and the
jit program cache stays frozen — detection and recovery cost no
recompiles and no extra host syncs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer
from repro.serve import (CANCELLED, DONE, DROPPED, FAILED, QUEUED,
                         AdmissionRejected, FaultInjector, FaultPlan,
                         Request, Scheduler, ServeEngine, SlotPool,
                         TraceRequest)
from repro.train.guards import GuardConfig, TrainGuard


def _smoke_cfg():
    return configs.smoke_config("llama3-8b")


@pytest.fixture(scope="module")
def llama():
    cfg = _smoke_cfg()
    return cfg, transformer.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def engine_mod(llama):
    cfg, params = llama
    eng = ServeEngine(params, cfg, max_slots=3, max_len=32, max_retries=2)
    eng.warmup()
    return eng


@pytest.fixture
def engine(engine_mod):
    """Shared warmed-up engine, reset to a clean slate per test (the
    compiled programs persist — that is the point of the contract)."""
    engine_mod.reset()
    engine_mod.hooks.clear()
    engine_mod.deadline_steps = None
    engine_mod.max_retries = 2
    engine_mod.retry_backoff_steps = 1
    engine_mod.scheduler.max_queue = None
    yield engine_mod


def _prompts(n, seed=0, lo=4, hi=10):
    rng = np.random.default_rng(seed)
    vocab = _smoke_cfg().vocab
    return [rng.integers(1, vocab, size=int(rng.integers(lo, hi)))
            .astype(np.int32) for _ in range(n)]


def _run_to_drain(eng, guard=400):
    while eng.scheduler.has_work() and guard:
        eng.step()
        guard -= 1
    assert guard, "engine failed to drain"


def _done_tokens(eng):
    return {r.rid: list(r.tokens) for r in eng._requests_done}


# ---------------------------------------------------------------------------
class TestSchedulerFailureStates:
    def test_bounded_queue_rejects(self):
        s = Scheduler(2, max_queue=2)
        s.submit(Request(0, [1, 2], 4))
        s.submit(Request(1, [1, 2], 4))
        with pytest.raises(AdmissionRejected, match="queue full"):
            s.submit(Request(2, [1, 2], 4))
        assert s.rejected == 1 and s.queue_depth == 2
        assert s.state_counts()["REJECTED"] == 1

    def test_shed_expired_anywhere_in_line(self):
        s = Scheduler(1)
        hold = Request(0, [1], 4)                       # no deadline
        dead = Request(1, [1], 4, deadline_steps=2)
        live = Request(2, [1], 4, deadline_steps=50)
        for r in (hold, dead, live):
            s.submit(r)
        assert s.shed_expired(2) == []                  # not yet: TTL is >
        shed = s.shed_expired(3)
        assert shed == [dead] and dead.state == DROPPED
        assert [r.rid for r in s._queue] == [0, 2]      # mid-line removal
        assert s.terminal_counts[DROPPED] == 1

    def test_cancel_queued(self):
        s = Scheduler(1)
        r = Request(0, [1], 4)
        s.submit(r)
        s.cancel_queued(r)
        assert r.state == CANCELLED and s.queue_depth == 0
        with pytest.raises(ValueError, match="CANCELLED"):
            s.cancel_queued(r)

    def test_requeue_goes_to_head(self):
        s = Scheduler(2)
        a, b = Request(0, [1], 4), Request(1, [1], 4)
        s.submit(a), s.submit(b)
        [adm] = s.pop_admissible(1, 0)
        assert adm is a and s.resident == 1
        s.requeue(a, arrival_step=5)
        assert s.resident == 0 and a.state == QUEUED
        assert [r.rid for r in s._queue] == [0, 1]      # head, not tail
        assert a.arrival_step == 5
        # backoff holds the line until arrival_step
        assert s.pop_admissible(2, now_step=4) == []
        assert s.pop_admissible(2, now_step=5)[0] is a

    def test_retire_terminal_states(self):
        s = Scheduler(1)
        r = Request(0, [1], 4)
        s.submit(r)
        s.pop_admissible(1, 0)
        with pytest.raises(ValueError, match="not terminal"):
            s.retire(r, state=QUEUED)
        s.retire(r, state=FAILED)
        assert r.state == FAILED and s.terminal_counts[FAILED] == 1
        with pytest.raises(ValueError, match="FAILED"):
            s.retire(r)                                 # terminal is final


class TestSlotPoolQuarantine:
    def test_quarantine_release_accounting(self):
        pool = SlotPool(_smoke_cfg(), 3, 32)
        a, b = pool.alloc(), pool.alloc()
        pool.quarantine(a)
        assert pool.quarantined == 1 and pool.occupancy == 1
        assert pool.frees == 0                          # free counts at release
        snap = pool.audit()
        assert snap == {"free": 1, "live": 1, "quarantined": 1,
                        "allocs": 2, "frees": 0}
        assert pool.release_quarantined() == [a]
        pool.free(b)
        assert pool.allocs == pool.frees == 2           # invariant restored
        assert pool.quarantined == 0 and pool.free_slots == 3
        pool.audit()

    def test_quarantine_requires_live(self):
        pool = SlotPool(_smoke_cfg(), 2, 32)
        with pytest.raises(ValueError, match="not live"):
            pool.quarantine(0)
        s = pool.alloc()
        pool.quarantine(s)
        with pytest.raises(ValueError, match="not live"):
            pool.free(s)                                # quarantined != live

    def test_audit_catches_corruption(self):
        pool = SlotPool(_smoke_cfg(), 2, 32)
        s = pool.alloc()
        pool._free.append(s)                            # slot in two states
        with pytest.raises(RuntimeError, match="two states"):
            pool.audit()
        pool = SlotPool(_smoke_cfg(), 2, 32)
        pool.alloc()
        pool.frees += 1                                 # counter drift
        with pytest.raises(RuntimeError, match="allocs"):
            pool.audit()


# ---------------------------------------------------------------------------
class TestFaultPlan:
    def test_plan_validation(self):
        with pytest.raises(ValueError, match="unknown kind"):
            FaultPlan().add(1, "meteor")
        with pytest.raises(ValueError, match="needs a rid"):
            FaultPlan().cancel(1, rid=None)

    def test_at_and_counts(self):
        plan = FaultPlan().nan_logits(3, rid=0).corrupt_row(3, rid=1) \
                          .cancel(5, rid=2)
        assert len(plan.at(3)) == 2 and len(plan.at(3, "nan_logits")) == 1
        assert plan.counts() == {"nan_logits": 1, "corrupt_row": 1,
                                 "cancel": 1}


class TestEngineFaultRecovery:
    def test_acceptance_nan_corrupt_drop(self, engine):
        """The ISSUE acceptance scenario: three fault kinds land, every
        victim recovers via quarantine + replay, survivors are
        token-exact vs the fault-free greedy run, zero slot leaks, and
        the program cache never grows."""
        prompts = _prompts(3, seed=1)
        # fault-free reference on the same engine (then reset)
        for p in prompts:
            engine.submit(p, 6)
        _run_to_drain(engine)
        ref = _done_tokens(engine)
        engine.reset()

        compiles = engine.compile_counts()
        rids = [engine.submit(p, 6) for p in prompts]
        plan = (FaultPlan()
                .drop_scatter(2, rid=rids[2])
                .nan_logits(3, rid=rids[0])
                .corrupt_row(4, rid=rids[1]))
        inj = FaultInjector(engine, plan)
        _run_to_drain(engine)
        s = engine.summary()

        assert dict(inj.injected) == {"drop_scatter": 1, "nan_logits": 1,
                                      "corrupt_row": 1}
        assert s["n_faults"] == 3 and s["n_retried"] == 3
        assert s["n_done"] == 3 and s["n_failed"] == 0
        assert s["retry_success_rate"] == 1.0
        assert not s["stalled"]
        got = _done_tokens(engine)
        assert got == ref                       # token-exact survivors
        # zero slot leaks, quarantine fully released
        assert engine.pool.allocs == engine.pool.frees
        assert engine.pool.occupancy == 0 == engine.pool.quarantined
        assert engine.pool.quarantines == 3
        engine.pool.audit()
        # the sentinel + injection cost no recompiles
        assert engine.compile_counts() == compiles
        # goodput == throughput here: every request finished
        assert s["goodput_tokens"] == s["total_tokens"]

    def test_faulted_step_emits_no_token(self, engine):
        """The poisoned round's sampled token must never reach the
        client — replay restarts from the last HEALTHY token."""
        [p] = _prompts(1, seed=3)
        rid = engine.submit(p, 5)
        plan = FaultPlan().nan_logits(2, rid=rid)
        FaultInjector(engine, plan)
        lens = []
        while engine.scheduler.has_work():
            engine.step()
            lens.append(len(engine._requests[rid].tokens))
        # token count never decreases and ends complete: the faulted
        # round contributed nothing
        assert all(b >= a for a, b in zip(lens, lens[1:]))
        assert lens[-1] == 5
        assert engine._requests[rid].state == DONE

    def test_retry_budget_exhausts_to_failed(self, engine):
        """A persistently poisoned request escalates to FAILED after
        max_retries replays; healthy neighbors still finish exactly."""
        prompts = _prompts(2, seed=2)
        for p in prompts:
            engine.submit(p, 5)
        _run_to_drain(engine)
        ref = _done_tokens(engine)
        engine.reset()

        rids = [engine.submit(p, 5) for p in prompts]
        plan = FaultPlan()
        for step in range(1, 40):                   # poison rid0 forever
            plan.nan_logits(step, rid=rids[0])
        inj = FaultInjector(engine, plan)
        _run_to_drain(engine)
        s = engine.summary()

        victim = engine._requests[rids[0]]
        assert victim.state == FAILED
        assert "retry budget exhausted" in victim.fail_reason
        assert victim.retries == 2                  # engine.max_retries
        assert s["n_failed"] == 1 and s["n_done"] == 1
        assert s["retry_success_rate"] == 0.0       # the one retried req died
        assert inj.injected["nan_logits"] == 3      # initial + 2 replays
        survivor = engine._requests[rids[1]]
        assert list(survivor.tokens) == ref[rids[1]]
        assert engine.pool.allocs == engine.pool.frees
        assert engine.pool.occupancy == 0
        # goodput excludes the failed request's emitted-then-lost tokens
        assert s["goodput_tokens"] == len(survivor.tokens)

    def test_cancel_storm_and_accounting(self, engine):
        prompts = _prompts(6, seed=4)
        rids = [engine.submit(p, 6) for p in prompts]
        # cancel two while queued (slots=3, so 3+ wait) and one resident
        plan = (FaultPlan().cancel(0, rid=rids[4]).cancel(0, rid=rids[5])
                .cancel(2, rid=rids[0]))
        inj = FaultInjector(engine, plan)
        _run_to_drain(engine)
        s = engine.summary()
        assert inj.injected["cancel"] == 3
        assert s["n_cancelled"] == 3 and s["n_done"] == 3
        assert s["n_requests"] == 6
        assert engine.pool.allocs == engine.pool.frees
        assert engine.pool.occupancy == 0
        # cancelling again or cancelling unknown rids is a no-op
        assert not engine.cancel(rids[0])
        assert not engine.cancel(999)

    def test_deadline_shedding(self, engine):
        """Queue TTLs shed overload instead of queueing forever: with 3
        slots and a 2-step TTL, late arrivals expire in line."""
        prompts = _prompts(8, seed=5)
        for p in prompts:
            engine.submit(p, 8, deadline_steps=2)
        _run_to_drain(engine)
        s = engine.summary()
        assert s["n_dropped"] > 0
        assert s["n_done"] + s["n_dropped"] == 8
        assert s["diagnostics"]["state_counts"][DROPPED] == s["n_dropped"]
        assert engine.pool.allocs == engine.pool.frees
        assert engine.pool.occupancy == 0

    def test_bounded_queue_backpressure(self, engine):
        engine.scheduler.max_queue = 2
        prompts = _prompts(4, seed=6)
        engine.submit(prompts[0], 4)
        engine.submit(prompts[1], 4)
        rid_before = engine._next_rid
        with pytest.raises(AdmissionRejected):
            engine.submit(prompts[2], 4)
        # the rejected submit never entered the system: no rid consumed
        assert engine._next_rid == rid_before
        assert engine.metrics.rejected == 1
        _run_to_drain(engine)
        s = engine.summary()
        assert s["n_rejected"] == 1 and s["n_done"] == 2

    def test_drain_graceful(self, engine):
        prompts = _prompts(5, seed=7)
        rids = [engine.submit(p, 6) for p in prompts]
        engine.step()                               # some become resident
        resident = [r for r in rids
                    if engine._requests[r].state not in (QUEUED,)]
        s = engine.drain()
        assert engine.scheduler.resident == 0
        assert engine.pool.occupancy == 0
        assert engine.pool.allocs == engine.pool.frees
        # resident requests finished; the still-queued were cancelled
        assert s["n_done"] >= len([r for r in resident
                                   if engine._requests[r].state == DONE])
        assert s["n_done"] + s["n_cancelled"] == 5

    def test_run_stalled_returns_partial_summary(self, engine):
        """Satellite: a budget-exhausted run keeps its metrics and says
        WHY, instead of raising them away."""
        trace = [TraceRequest(arrival_step=0, prompt=p, max_new_tokens=8)
                 for p in _prompts(3, seed=8)]
        s = engine.run(trace, max_steps=2)
        assert s["stalled"] is True
        d = s["diagnostics"]
        assert d["resident"] > 0 or d["queue_depth"] > 0
        assert set(d["state_counts"]) >= {QUEUED, "RESIDENT", DONE,
                                          CANCELLED, DROPPED, FAILED}
        assert d["pool"]["allocs"] >= d["pool"]["frees"]
        # and the engine is still coherent: drain finishes the work
        s2 = engine.drain()
        assert not s2["stalled"]
        assert engine.pool.occupancy == 0
        assert engine.pool.allocs == engine.pool.frees

    def test_seeded_sampling_replay_deterministic(self, llama):
        """Under temperature sampling the replay guarantee is
        seeded-deterministic: the same seed + same fault plan produce
        identical tokens across runs."""
        cfg, params = llama
        eng = ServeEngine(params, cfg, max_slots=2, max_len=32,
                          temperature=0.8, top_k=8, seed=7, max_retries=2)
        eng.warmup()

        def faulted_run():
            eng.reset()
            eng.hooks.clear()
            rid = eng.submit(np.arange(1, 6, dtype=np.int32), 5)
            FaultInjector(eng, FaultPlan().nan_logits(2, rid=rid))
            _run_to_drain(eng)
            assert eng.metrics.faults == 1
            return _done_tokens(eng)

        assert faulted_run() == faulted_run()


# ---------------------------------------------------------------------------
class TestCancelRetireRaces:
    """ISSUE 8 satellite: cancellation racing same-step retirement, and
    drain() called twice — both must be idempotent no-ops with
    reconciled counts."""

    def test_cancel_after_same_step_retirement(self, engine):
        [p] = _prompts(1)
        rid = engine.submit(p, 1)         # retires on its FIRST token
        engine.step()
        assert engine._requests[rid].state == DONE
        # the racing cancel arrives after retirement: safe no-op
        assert engine.cancel(rid) is False
        assert engine.cancel(rid) is False          # and again
        s = engine.summary()
        assert s["n_done"] == 1 and s["n_cancelled"] == 0
        assert engine.pool.allocs == engine.pool.frees

    def test_cancel_storm_every_step_reconciles(self, engine):
        """Cancel each rid at every step (most attempts race a request
        that is already terminal) — exactly one terminal state each."""
        rids = [engine.submit(p, 2) for p in _prompts(4, seed=11)]
        cancelled = set()
        guard = 100
        while engine.scheduler.has_work() and guard:
            for r in rids:
                if engine.cancel(r):
                    cancelled.add(r)
            engine.step()
            guard -= 1
        assert guard
        s = engine.summary()
        assert s["n_done"] + s["n_cancelled"] == len(rids)
        assert s["n_cancelled"] == len(cancelled)
        assert engine.pool.occupancy == 0
        assert engine.pool.allocs == engine.pool.frees

    def test_drain_twice_is_idempotent(self, engine):
        rids = [engine.submit(p, 4) for p in _prompts(3, seed=12)]
        engine.step()
        s1 = engine.drain()
        s2 = engine.drain()               # nothing left: same ledger
        for k in ("n_requests", "n_done", "n_cancelled", "n_dropped",
                  "n_failed", "total_tokens"):
            assert s1[k] == s2[k], k
        assert s2["n_done"] + s2["n_cancelled"] == len(rids)
        assert engine.scheduler.resident == 0
        assert engine.pool.occupancy == 0
        assert engine.pool.allocs == engine.pool.frees

    def test_evict_request_migrated_ledger(self, engine):
        """The router's eviction path: a resident request leaves as
        MIGRATED with its healthy tokens intact and no slot leak."""
        from repro.serve import MIGRATED
        [p] = _prompts(1, seed=13)
        rid = engine.submit(p, 6)
        engine.step(), engine.step()
        req = engine.evict_request(rid)
        assert req is not None and req.state == MIGRATED
        assert len(req.tokens) >= 1       # the replay prefix
        assert engine.evict_request(rid) is None    # idempotent
        s = engine.summary()
        assert s["n_migrated_out"] == 1 and s["n_done"] == 0
        assert engine.pool.allocs == engine.pool.frees


# ---------------------------------------------------------------------------
class TestTrainGuard:
    def test_config_validation(self):
        with pytest.raises(ValueError, match="spike_factor"):
            GuardConfig(spike_factor=0.5)
        with pytest.raises(ValueError, match="rollback_after"):
            GuardConfig(rollback_after=0)

    def test_nonfinite_escalation(self):
        g = TrainGuard(GuardConfig(rollback_after=3))
        assert g.observe(1.0, True) == TrainGuard.OK
        assert g.observe(float("nan"), False) == TrainGuard.SKIP
        assert g.observe(2.0, False) == TrainGuard.SKIP   # grads NaN, loss ok
        assert g.observe(1.0, False) == TrainGuard.ROLLBACK
        assert g.counters()["nonfinite"] == 3
        assert g.bad_streak == 0                          # reset by rollback

    def test_healthy_step_resets_streak(self):
        g = TrainGuard(GuardConfig(rollback_after=3))
        g.observe(1.0, True)
        g.observe(float("inf"), False)
        g.observe(float("inf"), False)
        assert g.observe(1.0, True) == TrainGuard.OK      # streak broken
        assert g.observe(float("inf"), False) == TrainGuard.SKIP
        assert g.rollbacks == 0

    def test_spike_detection_median_window(self):
        g = TrainGuard(GuardConfig(min_history=3, spike_factor=4.0))
        for loss in (1.0, 1.1, 0.9):
            assert g.observe(loss, True) == TrainGuard.OK
        assert g.observe(3.9, True) == TrainGuard.OK      # < 4x median
        assert g.observe(40.0, True) == TrainGuard.SKIP   # spike
        # the spike never entered the window: median still ~1
        assert g.median() < 2.0
        assert g.counters()["spikes"] == 1

    def test_no_spike_verdicts_before_history(self):
        g = TrainGuard(GuardConfig(min_history=5))
        assert g.observe(1.0, True) == TrainGuard.OK
        assert g.observe(1000.0, True) == TrainGuard.OK   # too early to judge

    def test_reset_history(self):
        g = TrainGuard(GuardConfig(min_history=2))
        g.observe(1.0, True), g.observe(1.0, True)
        g.reset_history()
        assert g.median() is None
        assert g.observe(500.0, True) == TrainGuard.OK    # fresh baseline


class TestTrainGuardRollback:
    """NaN-grad steps are skipped IN-JIT and consecutive bad steps roll
    back to the last good checkpoint, resuming with matching loss — the
    trainer half of the acceptance criteria, on a toy quadratic model
    (the real train_step shares the same all_finite + adamw skip path)."""

    def _setup(self):
        from repro.core.mixed_precision import all_finite
        from repro.optim import adamw

        oc = adamw.AdamWConfig(lr=1e-2, total_steps=100, warmup_steps=1)

        def loss_fn(p, batch):
            pred = batch["x"] @ p["w"]
            return jnp.mean((pred - batch["y"]) ** 2)

        @jax.jit
        def step_fn(p, opt, batch):
            loss, grads = jax.value_and_grad(loss_fn)(p, batch)
            finite = all_finite(grads)
            new_p, new_opt, m = adamw.update(oc, grads, opt, p,
                                             skip=~finite)
            return new_p, new_opt, {"loss": loss, "grads_finite": finite,
                                    **m}

        def batch_for(i, poisoned=False):
            rng = np.random.default_rng(i)
            x = rng.normal(size=(8, 4)).astype(np.float32)
            w_true = np.linspace(0.0, 1.0, 8, dtype=np.float32).reshape(4, 2)
            y = x @ w_true
            if poisoned:
                x = x.copy()
                x[0, 0] = np.nan
            return {"x": jnp.asarray(x), "y": jnp.asarray(y)}

        params = {"w": jnp.zeros((4, 2))}
        return step_fn, batch_for, params, adamw.init(params)

    def test_nan_step_applies_no_update(self):
        step_fn, batch_for, params, opt = self._setup()
        params, opt, _ = step_fn(params, opt, batch_for(0))
        w_before = np.asarray(params["w"])
        count_before = int(opt.count)
        params, opt, m = step_fn(params, opt, batch_for(1, poisoned=True))
        assert not bool(m["grads_finite"])
        np.testing.assert_array_equal(np.asarray(params["w"]), w_before)
        assert int(opt.count) == count_before      # optimizer clock frozen too

    def test_rollback_resumes_from_last_good_checkpoint(self, tmp_path):
        from repro.checkpointing.ckpt import CheckpointManager

        step_fn, batch_for, params, opt = self._setup()
        mgr = CheckpointManager(str(tmp_path / "g"), keep_last=2)
        guard = TrainGuard(GuardConfig(window=8, min_history=2,
                                       rollback_after=3))
        losses = {}
        step = 0
        while step < 6:
            params, opt, m = step_fn(params, opt, batch_for(step))
            assert guard.observe(float(m["loss"]),
                                 bool(m["grads_finite"])) == TrainGuard.OK
            losses[step] = float(m["loss"])
            step += 1
            if step == 4:
                mgr.save(step, {"params": params, "opt": opt},
                         extra={"step": step}, config="toy")

        # three consecutive NaN-grad steps: SKIP, SKIP, ROLLBACK
        verdicts = []
        for _ in range(3):
            params, opt, m = step_fn(params, opt,
                                     batch_for(step, poisoned=True))
            verdicts.append(guard.observe(float(m["loss"]),
                                          bool(m["grads_finite"])))
        assert verdicts == [TrainGuard.SKIP, TrainGuard.SKIP,
                            TrainGuard.ROLLBACK]

        latest = mgr.latest_step()
        assert latest == 4
        restored, extra = mgr.restore(
            latest, {"params": params, "opt": opt}, config="toy")
        params, opt = restored["params"], restored["opt"]
        step = extra["step"]
        guard.reset_history()

        # replaying the healthy stream from the checkpoint reproduces
        # the original trajectory exactly
        for replay in (4, 5):
            params, opt, m = step_fn(params, opt, batch_for(replay))
            assert float(m["loss"]) == losses[replay]
            assert guard.observe(float(m["loss"]),
                                 bool(m["grads_finite"])) == TrainGuard.OK
        assert guard.rollbacks == 1
