"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
shape + finiteness asserts; decode consistency vs full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.checkpoint import CheckpointConfig
from repro.core.mixed_precision import Policy
from repro.models import transformer

ARCHS = configs.list_archs()
KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, b=2, s=32):
    rng = np.random.default_rng(1)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
    }
    if cfg.encoder is not None:
        batch["frames"] = jnp.asarray(
            rng.normal(size=(b, cfg.encoder.n_frames, cfg.d_model))
            .astype(np.float32))
    if cfg.family == "vlm":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(b, 8, cfg.d_model)).astype(np.float32))
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s)[None, None], (3, b, s)).astype(jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = configs.smoke_config(arch)
    params = transformer.init_params(cfg, KEY)
    batch = _batch_for(cfg)
    logits, aux = transformer.forward(params, cfg, batch)
    assert logits.shape == (2, 32, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step_decreases_loss(arch):
    from repro.optim import adamw
    from repro.train.train_step import TrainConfig, build_train_step
    from repro.core.mixed_precision import LossScale

    cfg = configs.smoke_config(arch)
    params = transformer.init_params(cfg, KEY)
    batch = _batch_for(cfg, b=4, s=16)
    tc = TrainConfig(policy="full",
                     opt=adamw.AdamWConfig(lr=1e-2, warmup_steps=0,
                                           total_steps=100))
    step = jax.jit(build_train_step(cfg, tc))
    opt = adamw.init(params)
    ls = LossScale.noop()
    losses = []
    for _ in range(4):
        params, opt, ls, m = step(params, opt, ls, batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_remat_equals_standard(arch):
    """OpTorch S-C must not change the math (paper: 'same accuracy')."""
    cfg = configs.smoke_config(arch)
    params = transformer.init_params(cfg, KEY)
    batch = _batch_for(cfg)
    l1, _ = transformer.loss_fn(params, cfg, batch,
                                remat=CheckpointConfig(enabled=False))
    l2, _ = transformer.loss_fn(params, cfg, batch,
                                remat=CheckpointConfig(enabled=True))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)
    if arch not in ("llama3-8b", "deepseek-moe-16b", "mamba2-130m",
                    "hymba-1.5b"):
        return  # grad equality: one arch per family (compile-time budget)
    g1 = jax.grad(lambda p: transformer.loss_fn(
        p, cfg, batch, remat=CheckpointConfig(enabled=False))[0])(params)
    g2 = jax.grad(lambda p: transformer.loss_fn(
        p, cfg, batch, remat=CheckpointConfig(enabled=True))[0])(params)
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)


@pytest.mark.parametrize("arch", ["llama3-8b", "glm4-9b", "qwen2-vl-2b",
                                  "mamba2-130m", "hymba-1.5b", "minicpm3-4b",
                                  "whisper-base"])
def test_decode_matches_forward(arch):
    """Greedy decode logits must match teacher-forced forward logits."""
    cfg = configs.smoke_config(arch)
    params = transformer.init_params(cfg, KEY)
    b, s = 2, 12
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    kw = {}
    if cfg.encoder is not None:
        frames = jnp.asarray(rng.normal(
            size=(b, cfg.encoder.n_frames, cfg.d_model)).astype(np.float32))
        batch["frames"] = frames
        kw["enc_out"] = transformer._run_encoder(params, cfg, frames,
                                                 Policy.full())
    if cfg.family == "vlm":
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s)[None, None], (3, b, s)).astype(jnp.int32)
    full_logits, _ = transformer.forward(params, cfg, batch)

    cache = transformer.init_cache(cfg, b, s, quantized=False,
                                   dtype=jnp.float32)
    step_logits = []
    for t in range(s):
        lg, cache = transformer.decode_step(params, cfg, cache, toks[:, t],
                                            quantized=False, **kw)
        step_logits.append(lg)
    dec = np.stack([np.asarray(l) for l in step_logits], 1)
    np.testing.assert_allclose(dec, np.asarray(full_logits),
                               atol=2e-3, rtol=2e-2)


def test_quantized_cache_close_to_exact():
    cfg = configs.smoke_config("llama3-8b")
    params = transformer.init_params(cfg, KEY)
    b, s = 2, 10
    toks = jnp.asarray(np.random.default_rng(5).integers(0, cfg.vocab, (b, s)),
                       jnp.int32)
    cache_q = transformer.init_cache(cfg, b, s, quantized=True)
    cache_f = transformer.init_cache(cfg, b, s, quantized=False,
                                     dtype=jnp.float32)
    for t in range(s):
        lq, cache_q = transformer.decode_step(params, cfg, cache_q,
                                              toks[:, t], quantized=True)
        lf, cache_f = transformer.decode_step(params, cfg, cache_f,
                                              toks[:, t], quantized=False)
    # int8 cache must preserve the argmax token and be close in value
    assert (np.asarray(lq).argmax(-1) == np.asarray(lf).argmax(-1)).all()
    np.testing.assert_allclose(np.asarray(lq), np.asarray(lf), atol=0.05,
                               rtol=0.05)


def test_prefill_cache_matches_incremental():
    cfg = configs.smoke_config("llama3-8b")
    params = transformer.init_params(cfg, KEY)
    b, s = 2, 8
    toks = jnp.asarray(np.random.default_rng(6).integers(0, cfg.vocab, (b, s)),
                       jnp.int32)
    logits, aux = transformer.forward(params, cfg, {"tokens": toks},
                                      build_cache=True, cache_quantized=True)
    cache_pf = aux["cache"]
    # continue decoding one token; compare against incremental-built cache
    cache_inc = transformer.init_cache(cfg, b, s + 4, quantized=True)
    for t in range(s):
        lg_inc, cache_inc = transformer.decode_step(params, cfg, cache_inc,
                                                    toks[:, t])
    np.testing.assert_allclose(np.asarray(lg_inc), np.asarray(logits[:, -1]),
                               atol=0.05, rtol=0.05)
    # prefill cache continues correctly
    nxt = jnp.asarray(logits[:, -1].argmax(-1), jnp.int32)
    # pad prefill cache to the incremental cache length for the next step
    assert int(cache_pf["pos"]) == s


def test_two_tier_cache_matches_uniform():
    """Rolling window buffers must reproduce the uniform-cache decode,
    including after wraparound (hymba two-tier serving path)."""
    cfg = configs.smoke_config("hymba-1.5b")  # window=16, global=(0,)
    params = transformer.init_params(cfg, KEY)
    b, steps = 2, 24  # beyond the window to exercise wraparound
    rng = np.random.default_rng(4)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, steps)), jnp.int32)
    c_uni = transformer.init_cache(cfg, b, steps, quantized=True)
    c_tt = transformer.init_cache_two_tier(cfg, b, steps, quantized=True)
    for t in range(steps):
        l_uni, c_uni = transformer.decode_step(params, cfg, c_uni, toks[:, t])
        l_tt, c_tt = transformer.decode_step_two_tier(params, cfg, c_tt,
                                                      toks[:, t])
    assert (np.asarray(l_uni).argmax(-1) == np.asarray(l_tt).argmax(-1)).all()
    rel = float(jnp.abs(l_uni - l_tt).max()) / (float(jnp.abs(l_uni).max()) + 1e-9)
    assert rel < 0.05
