"""Trainable flash attention: interpret-mode gradient parity vs the jnp
oracle, dispatch guards, and end-to-end differentiability of the
``attn_backend="pallas"`` training path (custom_vjp, O(S*D) residuals)."""
import dataclasses as dc
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels.flash import kernel as flash_kernel, ops as flash_ops, \
    ref as flash_ref
from repro.models import transformer

RNG = np.random.default_rng(11)


def _qkv(b, h, hkv, s, d, dtype=np.float32):
    q = jnp.asarray(RNG.normal(size=(b, h, s, d)).astype(dtype))
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(dtype))
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(dtype))
    return q, k, v


class TestFlashGradParity:
    """jax.grad of the Pallas backward (interpret mode) vs jnp autodiff of
    the exact reference — the ISSUE 2 acceptance bar (<= 1e-3 max abs)."""

    @pytest.mark.parametrize("b,h,hkv,s,d,window,causal", [
        (1, 4, 4, 256, 64, 0, True),     # MHA causal
        (2, 8, 2, 256, 64, 0, True),     # GQA 4:1
        (2, 8, 1, 256, 64, 0, True),     # MQA
        (1, 4, 2, 200, 32, 0, True),     # padding path (pads 200 -> 256)
        (1, 4, 4, 256, 64, 64, True),    # sliding window
        (1, 4, 4, 200, 64, 100, True),   # window + padding
        (1, 2, 2, 200, 64, 0, False),    # non-causal + padded KV masking
        (1, 2, 2, 256, 64, 0, False),    # non-causal
    ])
    def test_grads_match_ref(self, b, h, hkv, s, d, window, causal):
        q, k, v = _qkv(b, h, hkv, s, d)
        t = jnp.asarray(RNG.normal(size=(b, h, s, d)).astype(np.float32))

        def loss(fn):
            return lambda q, k, v: jnp.sum(fn(q, k, v) * t)

        g_int = jax.grad(loss(lambda q, k, v: flash_ops.flash_attention(
            q, k, v, causal=causal, window=window, backend="interpret")),
            argnums=(0, 1, 2))(q, k, v)
        g_ref = jax.grad(loss(lambda q, k, v: flash_ref.flash_ref(
            q, k, v, causal=causal, window=window)),
            argnums=(0, 1, 2))(q, k, v)
        for name, a, b_ in zip("qkv", g_int, g_ref):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b_), atol=1e-3,
                err_msg=f"d{name} mismatch")

    def test_sm_scale_override(self):
        q, k, v = _qkv(1, 2, 2, 256, 64)
        scale = 0.05
        f_int = lambda q, k, v: jnp.sum(flash_ops.flash_attention(
            q, k, v, sm_scale=scale, backend="interpret") ** 2)
        f_ref = lambda q, k, v: jnp.sum(flash_ref.flash_ref(
            q, k, v, sm_scale=scale) ** 2)
        g_int = jax.grad(f_int, argnums=(0, 1, 2))(q, k, v)
        g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(g_int, g_ref):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                       atol=1e-3)

    def test_fwd_stats_consistent_with_output(self):
        """o == (exp-weighted V) / l with the saved (m, l): the backward's
        recompute contract."""
        b, h, s, d = 1, 2, 256, 64
        q, k, v = _qkv(b, h, h, s, d)
        o, m, l = flash_kernel.flash_attention_fwd_pallas(
            q.reshape(b * h, s, d), k.reshape(b * h, s, d),
            v.reshape(b * h, s, d), interpret=True)
        lse = np.asarray(m) + np.log(np.maximum(np.asarray(l), 1e-30))
        logits = np.einsum("hqd,hkd->hqk", np.asarray(q[0]),
                           np.asarray(k[0])) * d ** -0.5
        mask = np.tril(np.ones((s, s), bool))
        p = np.where(mask, np.exp(logits - lse[:, :, None]), 0.0)
        o_rec = np.einsum("hqk,hkd->hqd", p, np.asarray(v[0]))
        np.testing.assert_allclose(np.asarray(o), o_rec, atol=2e-5)


class TestDispatchGuards:
    def test_pallas_head_dim_falls_back_with_warning(self):
        q, k, v = _qkv(1, 2, 2, 256, 32)       # head_dim 32: Mosaic-illegal
        flash_ops._WARNED_FALLBACKS.clear()
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            out = flash_ops.flash_attention(q, k, v, backend="pallas")
            out2 = flash_ops.flash_attention(q, k, v, backend="pallas")
        msgs = [str(x.message) for x in w
                if "falling back" in str(x.message)]
        assert len(msgs) == 1, msgs               # one-time warning
        assert "head_dim=32" in msgs[0]           # names the offending shape
        ref = flash_ref.flash_ref(q, k, v)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))
        np.testing.assert_array_equal(np.asarray(out2), np.asarray(ref))

    def test_pallas_short_seq_falls_back_with_warning(self):
        q, k, v = _qkv(1, 2, 2, 40, 64)           # s=40 < one 128 block
        flash_ops._WARNED_FALLBACKS.clear()
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            out = flash_ops.flash_attention(q, k, v, backend="pallas")
        msgs = [str(x.message) for x in w
                if "falling back" in str(x.message)]
        assert len(msgs) == 1 and "40" in msgs[0]
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(flash_ref.flash_ref(q, k, v)))

    def test_interpret_not_restricted(self):
        """The interpreter runs Mosaic-illegal shapes — no fallback."""
        q, k, v = _qkv(1, 2, 2, 256, 32)
        assert flash_ops.unsupported_reason(q, k, v,
                                            backend="interpret") is None

    def test_gqa_indivisible_raises_on_every_backend(self):
        """n_heads % n_kv != 0 is an invalid GQA input everywhere (even
        the ref path groups query heads over KV heads) — a clear error
        naming the shapes, not an opaque reshape crash."""
        q, k, v = _qkv(1, 6, 4, 256, 64)
        for backend in ("ref", "interpret", "pallas"):
            with pytest.raises(ValueError, match="n_heads=6"):
                flash_ops.flash_attention(q, k, v, backend=backend)

    def test_unknown_backend_raises(self):
        q, k, v = _qkv(1, 2, 2, 256, 64)
        with pytest.raises(ValueError, match="unknown backend"):
            flash_ops.flash_attention(q, k, v, backend="mosaic")


class TestEndToEnd:
    def test_block_grads_match_jnp_backend(self):
        """One transformer stack: grads through attn_backend='interpret'
        (Pallas custom_vjp backward) vs 'jnp' (autodiff)."""
        cfg = configs.smoke_config("llama3-8b")
        cfg_flash = dc.replace(cfg, attn_backend="interpret")
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        batch = {
            "tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (2, 32)),
                                  jnp.int32),
            "labels": jnp.asarray(RNG.integers(0, cfg.vocab, (2, 32)),
                                  jnp.int32),
        }
        g_jnp = jax.grad(lambda p: transformer.loss_fn(
            p, cfg, batch)[0])(params)
        g_fla = jax.grad(lambda p: transformer.loss_fn(
            p, cfg_flash, batch)[0])(params)
        for a, b in zip(jax.tree_util.tree_leaves(g_jnp),
                        jax.tree_util.tree_leaves(g_fla)):
            scale = float(jnp.abs(a).max()) + 1e-9
            assert float(jnp.abs(a - b).max()) / scale < 1e-3

    def test_pallas_backend_differentiable_abstractly(self):
        """Regression: jax.grad through attn_backend='pallas' must trace
        (the custom_vjp covers the backward; before ISSUE 2 this raised).
        eval_shape never lowers to Mosaic, so it runs on any host.
        head_dim is pinned to a Mosaic-legal 64 (the smoke config's 16
        would silently fall back to ref and make this test vacuous)."""
        cfg = dc.replace(configs.smoke_config("llama3-8b"),
                         attn_backend="pallas", n_layers=1, head_dim=64)
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        batch = {
            "tokens": jax.ShapeDtypeStruct((1, 128), jnp.int32),
            "labels": jax.ShapeDtypeStruct((1, 128), jnp.int32),
        }
        flash_ops._WARNED_FALLBACKS.clear()
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            grads = jax.eval_shape(
                jax.grad(lambda p, b: transformer.loss_fn(p, cfg, b)[0]),
                params, batch)
        assert not [x for x in w if "falling back" in str(x.message)], \
            "pallas path fell back to ref — the custom_vjp was not traced"
        leaves = jax.tree_util.tree_leaves(grads)
        assert leaves and all(x.shape is not None for x in leaves)

    def test_flash_residuals_are_subquadratic(self):
        """vjp residual bytes: custom_vjp path must beat jnp autodiff of
        the reference (which stores the S^2 probability matrix)."""
        b, h, s, d = 1, 4, 1024, 64
        sds = [jax.ShapeDtypeStruct((b, h, s, d), jnp.float32)] * 3

        def resid_bytes(fn):
            out = jax.eval_shape(lambda q, k, v: jax.vjp(fn, q, k, v), *sds)
            return sum(x.size * x.dtype.itemsize
                       for x in jax.tree_util.tree_leaves(out))

        flash = resid_bytes(lambda q, k, v: flash_ops.flash_attention(
            q, k, v, backend="interpret"))
        jnp_path = resid_bytes(lambda q, k, v: flash_ref.flash_ref(q, k, v))
        assert flash < jnp_path / 2, (flash, jnp_path)
