"""Durable serving (ISSUE 9): whole-router crash recovery from the
write-ahead journal, and process-isolated replica workers.

The acceptance scenario: a seeded fleet run is killed ``-9`` mid-flight
(modelled by abandoning the Router object and force-draining the
engines — the OS reclaimed the process; the compiled programs survive
because the test keeps the jit cache, exactly as a restarted server
re-warms to the same programs).  A FRESH router + reopened journal must
finish every in-flight request token-exact vs a crash-free reference
under greedy decoding, with one terminal per journaled SUBMIT, zero
slot leaks, and frozen compile counts.  Crashes are also injected at
the worst seam — between the wal_submit append and its placement — and
into the journal file itself (torn final record).

Worker tests spawn real subprocesses: ``kill()`` is a real SIGKILL, the
stall detector reads heartbeat-backed liveness, and the breaker is
exercised across the process boundary.
"""
from __future__ import annotations

import os
import shutil

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import transformer
from repro.serve import (DEAD, DONE, QUARANTINED, TERMINAL,
                         AdmissionRejected, BreakerConfig, FaultPlan,
                         FleetFaultInjector, RequestJournal, Router,
                         ServeEngine, SimulatedCrash, WorkerProxy,
                         crash_after_appends, spawn_worker, tear_tail)


def _smoke_cfg():
    return configs.smoke_config("llama3-8b")


@pytest.fixture(scope="module")
def llama():
    cfg = _smoke_cfg()
    return cfg, transformer.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def engines_mod(llama):
    """Two warmed greedy replicas, deliberately SMALL (2 slots each) so
    the 6-request scenario is still mid-flight at the crash point."""
    cfg, params = llama
    out = []
    for _ in range(2):
        e = ServeEngine(params, cfg, max_slots=2, max_len=32,
                        prompt_buckets=(16,), sampler_keys="request")
        e.warmup()
        out.append(e)
    return out


def _reset(engines):
    for e in engines:
        e.reset()
        e.hooks.clear()
    return engines


def _prompts(n=6, seed=0):
    rng = np.random.RandomState(seed)
    vocab = _smoke_cfg().vocab
    return [rng.randint(1, vocab, size=rng.randint(4, 9)).astype(np.int32)
            for _ in range(n)]


MAX_NEW = 8


def force_drain(engines):
    """Model ``kill -9`` of the router process: every engine-side
    request just VANISHES (evict, then reset).  The compiled programs
    survive — a restarted server re-warms to the same jit cache."""
    for e in engines:
        for rid, st in list(e.request_states().items()):
            if st["state"] not in TERMINAL:
                e.evict_request(rid)
        e.reset()


def _drive(router, guard=600):
    while router.live_requests() > 0 and guard:
        router.step()
        guard -= 1
    assert guard, "fleet failed to drain"


def _run_reference(engines):
    """Crash-free journal-less run: the token-exactness oracle."""
    router = Router(_reset(engines))
    gids = [router.submit(p, MAX_NEW) for p in _prompts()]
    _drive(router)
    ref = {g: list(router.request(g).tokens) for g in gids}
    assert all(router.request(g).state == DONE for g in gids)
    force_drain(engines)
    return ref


def _crash_midflight(engines, path, *, steps=4, snapshot_every=0):
    """Journaled run killed after ``steps`` router steps; returns the
    (closed) journal path with requests still live on disk."""
    j = RequestJournal(path, snapshot_every=snapshot_every)
    router = Router(_reset(engines), journal=j)
    for p in _prompts():
        router.submit(p, MAX_NEW)
    for _ in range(steps):
        router.step()
    n_live = router.live_requests()
    assert n_live > 0, "scenario must crash mid-flight"
    del router                      # kill -9: no drain, no goodbye
    force_drain(engines)
    j.close()
    return n_live


# ---------------------------------------------------------------------------
class TestCrashRecover:
    def test_whole_router_crash_recovers_token_exact(self, engines_mod):
        ref = _run_reference(engines_mod)
        jp = "/tmp/test_recovery_wal_main.jsonl"
        for stale in (jp, jp + ".snap"):
            if os.path.exists(stale):
                os.remove(stale)
        compiles = [e.compile_counts() for e in engines_mod]
        _crash_midflight(engines_mod, jp, snapshot_every=10)

        j2 = RequestJournal(jp)     # reopen: snapshot + tail replay
        router = Router(_reset(engines_mod), journal=j2)
        info = router.recover()
        assert info["n_recovered"] == len(ref)
        assert info["n_recovered"] == (info["n_placed"] + info["n_done"]
                                       + info["n_pending"])
        assert info["n_failed"] == 0
        _drive(router)

        # token-exact under greedy: regenerated tokens (the fsync-lag
        # window past the last durable record) match the durable prefix
        # they extend
        for g, toks in ref.items():
            fr = router.request(g)
            assert fr.state == DONE
            assert list(fr.tokens) == toks, f"gid {g} diverged"
        rec = router.reconcile()
        assert rec["ok"], rec
        assert rec["checks"]["journal_accounted"]
        assert rec["journal"]["n_live"] == 0
        assert rec["journal"]["n_terminals"] == len(ref)
        fleet = router.summary()["fleet"]
        assert fleet["n_recovered"] == len(ref)
        assert fleet["recovery_replay_success"] == 1.0
        assert all(e.pool.audit() for e in engines_mod)   # zero leaks
        assert [e.compile_counts() for e in engines_mod] == compiles
        j2.close()

    def test_recover_is_idempotent(self, engines_mod):
        jp = "/tmp/test_recovery_wal_idem.jsonl"
        if os.path.exists(jp):
            os.remove(jp)
        n_live = _crash_midflight(engines_mod, jp)
        j2 = RequestJournal(jp)
        router = Router(_reset(engines_mod), journal=j2)
        first = router.recover()
        assert first["n_recovered"] == n_live
        second = router.recover()   # run twice BEFORE driving
        assert second["n_recovered"] == second["n_placed"] == 0
        assert second["n_skipped"] == n_live
        _drive(router)
        rec = router.reconcile()
        assert rec["ok"], rec
        assert all(e.pool.audit() for e in engines_mod)
        j2.close()

    def test_snapshot_tail_and_full_history_recover_identically(
            self, engines_mod, tmp_path):
        """Satellite 3: recovery from snapshot+tail vs a full-history
        scan of the same journal — identical terminal sets, zero slot
        leaks either way."""
        jp = str(tmp_path / "wal.jsonl")
        _crash_midflight(engines_mod, jp, snapshot_every=5)
        assert os.path.exists(jp + ".snap")
        jp_full = str(tmp_path / "wal_full.jsonl")
        shutil.copy(jp, jp_full)    # same records, no .snap sidecar

        results = []
        for path in (jp, jp_full):
            j = RequestJournal(path)
            router = Router(_reset(engines_mod), journal=j)
            router.recover()
            _drive(router)
            assert router.reconcile()["ok"]
            results.append({g: (fr.state, tuple(fr.tokens))
                            for g, fr in sorted(router._reqs.items())})
            assert all(e.pool.audit() for e in engines_mod)
            force_drain(engines_mod)
            j.close()
        assert results[0] == results[1]

    def test_crash_between_wal_append_and_placement(self, engines_mod):
        """The worst seam: the wal_submit record is durable but the
        router died before placing it.  Recovery must still run that
        request to completion."""
        jp = "/tmp/test_recovery_wal_seam.jsonl"
        for stale in (jp, jp + ".snap"):
            if os.path.exists(stale):
                os.remove(stale)
        j = RequestJournal(jp)
        router = Router(_reset(engines_mod), journal=j)
        prompts = _prompts()
        for p in prompts[:-1]:
            router.submit(p, MAX_NEW)
        crash_after_appends(j, 1)   # next append IS the final submit
        with pytest.raises(SimulatedCrash):
            router.submit(prompts[-1], MAX_NEW)
        del router
        force_drain(engines_mod)
        j.close()

        j2 = RequestJournal(jp)
        assert j2.state.n_live == len(prompts)   # incl. the unplaced one
        assert j2.state.live[len(prompts) - 1]["placements"] == 0
        router = Router(_reset(engines_mod), journal=j2)
        info = router.recover()
        assert info["n_recovered"] == len(prompts)
        _drive(router)
        fr = router.request(len(prompts) - 1)
        assert fr.state == DONE and len(fr.tokens) == MAX_NEW
        rec = router.reconcile()
        assert rec["ok"] and rec["checks"]["journal_accounted"]
        assert all(e.pool.audit() for e in engines_mod)
        j2.close()

    @pytest.mark.parametrize("crash_at", [2, 5, 9, 14])
    def test_seeded_crash_point_sweep(self, engines_mod, tmp_path,
                                      crash_at):
        """Kill the router after the Nth journal append for seeded
        arbitrary N — submit loop or step loop, placement or token
        record, it must not matter: one terminal per journaled SUBMIT."""
        jp = str(tmp_path / f"wal{crash_at}.jsonl")
        j = RequestJournal(jp)
        router = Router(_reset(engines_mod), journal=j)
        crash_after_appends(j, crash_at)
        with pytest.raises(SimulatedCrash):
            for p in _prompts():
                router.submit(p, MAX_NEW)
            for _ in range(200):
                router.step()
        del router
        force_drain(engines_mod)
        j.close()

        j2 = RequestJournal(jp)
        n_submitted = j2.state.n_submits
        assert n_submitted > 0
        router = Router(_reset(engines_mod), journal=j2)
        router.recover()
        _drive(router)
        rec = router.reconcile()
        assert rec["ok"], (crash_at, rec)
        assert rec["journal"]["n_terminals"] == n_submitted
        assert rec["journal"]["n_live"] == 0
        assert rec["journal"]["duplicate_terminals"] == 0
        assert all(e.pool.audit() for e in engines_mod)
        force_drain(engines_mod)
        j2.close()

    def test_torn_final_record_recovers(self, engines_mod, tmp_path):
        """kill -9 mid-write: the final journal record is half a line.
        Recovery drops exactly that record and regenerates the lost
        tokens deterministically."""
        jp = str(tmp_path / "wal.jsonl")
        _crash_midflight(engines_mod, jp)
        tear_tail(jp)
        j2 = RequestJournal(jp)     # tail scan ignores the torn bytes
        router = Router(_reset(engines_mod), journal=j2)
        info = router.recover()
        assert info["n_recovered"] > 0
        _drive(router)
        rec = router.reconcile()
        assert rec["ok"] and rec["checks"]["journal_accounted"], rec
        assert all(e.pool.audit() for e in engines_mod)
        force_drain(engines_mod)
        j2.close()


# ---------------------------------------------------------------------------
class TestSampledRecovery:
    @pytest.fixture(scope="class")
    def sampled_engines(self, llama):
        cfg, params = llama
        out = []
        for _ in range(2):
            e = ServeEngine(params, cfg, max_slots=2, max_len=32,
                            prompt_buckets=(16,), temperature=0.7,
                            top_k=8, seed=13, sampler_keys="request")
            e.warmup()
            out.append(e)
        return out

    def test_sampled_recovery_is_key_exact(self, sampled_engines,
                                           tmp_path):
        """Request-scoped keys make sampled recovery deterministic: the
        regenerated suffix draws ``fold_in(base, gid)`` keys indexed by
        position, so the recovered trajectory equals the uncrashed one
        token for token — not just in distribution."""
        ref = _run_reference(sampled_engines)
        jp = str(tmp_path / "wal.jsonl")
        _crash_midflight(sampled_engines, jp)
        j2 = RequestJournal(jp)
        router = Router(_reset(sampled_engines), journal=j2)
        router.recover()
        _drive(router)
        for g, toks in ref.items():
            fr = router.request(g)
            assert fr.state == DONE
            assert list(fr.tokens) == toks, f"gid {g} diverged (sampled)"
        assert router.reconcile()["ok"]
        assert all(e.pool.audit() for e in sampled_engines)
        force_drain(sampled_engines)
        j2.close()


# ---------------------------------------------------------------------------
WORKER_KWARGS = dict(max_slots=2, max_len=32, prompt_buckets=(16,),
                     sampler_keys="request")


@pytest.fixture(scope="module")
def worker_mod():
    """One warmed subprocess replica, shared by the healthy-path tests
    (reset between).  Killed-worker tests spawn their own disposable."""
    w = spawn_worker(kwargs=WORKER_KWARGS)
    yield w
    w.shutdown()


class TestWorkerProxy:
    def test_rpc_roundtrip(self, worker_mod):
        w = worker_mod
        w.reset()
        assert w.ping()
        assert w.alive and w.pid > 0
        assert w.sampler_keys == "request" and w.temperature == 0.0
        rid = w.submit(np.arange(1, 6, dtype=np.int32), 4)
        guard = 50
        while w.request_states()[rid]["state"] not in TERMINAL and guard:
            w.step()
            guard -= 1
        st = w.request_states()[rid]
        assert st["state"] == DONE and len(st["tokens"]) == 4
        assert w.heartbeat_age() < 60.0
        s = w.summary()
        assert s["n_done"] == 1 and not s.get("dead")
        assert w.compile_counts()     # warm cache shipped in the hello
        assert w.pool.audit()
        w.reset()

    def test_worker_matches_in_process_engine(self, worker_mod,
                                              engines_mod):
        """Same factory recipe, same greedy tokens — the pipe is
        transparent to the trajectory."""
        w = worker_mod
        w.reset()
        e = _reset(engines_mod)[0]
        prompt = _prompts(1, seed=3)[0]
        out = {}
        for eng in (w, e):
            rid = eng.submit(prompt, 6)
            guard = 50
            while eng.request_states()[rid]["state"] not in TERMINAL \
                    and guard:
                eng.step()
                guard -= 1
            out[id(eng)] = list(eng.request_states()[rid]["tokens"])
        vals = list(out.values())
        assert vals[0] == vals[1]
        w.reset()
        force_drain([e])

    def test_mixed_fleet_runs_and_reconciles(self, worker_mod,
                                             engines_mod):
        """A Router over one in-process engine and one subprocess
        worker — the same replica interface either side of the pipe."""
        w = worker_mod
        w.reset()
        engines = [_reset(engines_mod)[0], w]
        router = Router(engines)
        gids = [router.submit(p, 4) for p in _prompts(4, seed=5)]
        _drive(router)
        assert all(router.request(g).state == DONE for g in gids)
        rec = router.reconcile()
        assert rec["ok"], rec
        assert router.summary()["fleet"]["n_done"] == len(gids)
        w.reset()
        force_drain([engines[0]])

    def test_sigkill_marks_dead_and_rejects(self):
        w = spawn_worker(kwargs=WORKER_KWARGS)
        rid = w.submit(np.arange(1, 5, dtype=np.int32), 4)
        w.step()
        assert w.terminate()          # real SIGKILL
        assert not w.alive
        with pytest.raises(AdmissionRejected):
            w.submit(np.arange(1, 4, dtype=np.int32), 2)
        s = w.summary()
        assert s.get("dead") is True
        # the dead ledger still closes: evict flows through the mirror
        assert w.request_states()[rid]["state"] not in (DONE,)
        assert w.terminate() is False     # idempotent

    def test_worker_sigkill_midflight_breaker_failover(self, engines_mod):
        """The acceptance path across the process boundary: a worker is
        SIGKILLed behind the router's back mid-run; the breaker's stall
        detector (heartbeat-dead + holding work) quarantines it, every
        victim finishes on the surviving replica, and the fleet
        reconciles with zero leaks."""
        w = spawn_worker(kwargs=WORKER_KWARGS)
        engines = [_reset(engines_mod)[0], w]
        breaker = BreakerConfig(window_steps=8, stall_steps=2,
                                cooldown_steps=4)
        router = Router(engines, breaker=breaker)
        plan = FaultPlan().worker_sigkill(3, replica=1)
        inj = FleetFaultInjector(router, plan)   # self-installs pre_step
        gids = [router.submit(p, MAX_NEW) for p in _prompts(6, seed=9)]
        _drive(router)
        assert inj.injected["worker_sigkill"] == 1
        assert not w.alive
        assert router.health[1] in (QUARANTINED, DEAD)
        for g in gids:
            assert router.request(g).state == DONE, g
        rec = router.reconcile()
        assert rec["ok"], rec
        assert engines[0].pool.audit()
        assert w.pool.audit()         # dead ledger closed, no leaks
        force_drain([engines[0]])
