"""Per-kernel Pallas (interpret=True) vs pure-jnp oracle, with shape/dtype
sweeps as required for every kernel in kernels/."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import encoding
from repro.kernels.pack import kernel as pack_kernel, ops as pack_ops, \
    ref as pack_ref
from repro.kernels.kvq import kernel as kvq_kernel, ops as kvq_ops, \
    ref as kvq_ref
from repro.kernels.ssd import kernel as ssd_kernel, ops as ssd_ops, \
    ref as ssd_ref

RNG = np.random.default_rng(7)


# ---------------------------------------------------------------------------
# pack: E-D codec kernel
# ---------------------------------------------------------------------------
class TestPackKernel:
    @pytest.mark.parametrize("r,c", [(8, 128), (64, 512), (128, 1024),
                                     (16, 256)])
    def test_decode_matches_ref(self, r, c):
        x = jnp.asarray(RNG.integers(0, 2 ** 32, (r, c), dtype=np.uint32))
        out = pack_kernel.decode_pallas(x, interpret=True)
        np.testing.assert_allclose(np.asarray(out),
                                   np.asarray(pack_ref.decode_ref(x)),
                                   atol=1e-7)

    @pytest.mark.parametrize("scale,shift", [(1 / 255.0, 0.0), (2.0, -1.0)])
    def test_decode_normalization(self, scale, shift):
        x = jnp.asarray(RNG.integers(0, 2 ** 32, (8, 128), dtype=np.uint32))
        out = pack_kernel.decode_pallas(x, scale=scale, shift=shift,
                                        interpret=True)
        ref = pack_ref.decode_ref(x, scale, shift)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)

    @pytest.mark.parametrize("shape", [(8, 32, 32, 3), (4, 17, 5, 1),
                                       (12, 7, 7, 3)])
    def test_ops_roundtrip_arbitrary_shapes(self, shape):
        imgs = RNG.integers(0, 256, shape, dtype=np.uint8)
        packed = jnp.asarray(np.asarray(encoding.pack_u8_to_u32(imgs)))
        for backend in ("ref", "interpret"):
            dec = pack_ops.decode(packed, backend=backend)
            np.testing.assert_allclose(
                np.asarray(dec), imgs.astype(np.float32) / 255.0, atol=1e-7)
            enc = pack_ops.encode(jnp.asarray(imgs), backend=backend)
            np.testing.assert_array_equal(np.asarray(enc), np.asarray(packed))


# ---------------------------------------------------------------------------
# kvq: int8 KV flash-decode kernel
# ---------------------------------------------------------------------------
class TestKvqKernel:
    @pytest.mark.parametrize("b,h,hkv,s,d", [
        (1, 4, 4, 512, 64),      # MHA
        (2, 8, 2, 1024, 64),     # GQA 4:1
        (2, 8, 1, 512, 128),     # MQA
        (3, 6, 2, 768, 32),      # odd batch, s % 256
    ])
    def test_matches_ref(self, b, h, hkv, s, d):
        q = jnp.asarray(RNG.normal(size=(b, h, d)).astype(np.float32))
        k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(np.float32))
        v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(np.float32))
        kq, ks = kvq_ref.quantize_kv(k)
        vq, vs = kvq_ref.quantize_kv(v)
        lengths = jnp.asarray(RNG.integers(1, s + 1, size=(b,)))
        o_ref = kvq_ops.decode_attention(q, kq, ks, vq, vs, lengths=lengths,
                                         backend="ref")
        o_int = kvq_ops.decode_attention(q, kq, ks, vq, vs, lengths=lengths,
                                         backend="interpret")
        np.testing.assert_allclose(np.asarray(o_int), np.asarray(o_ref),
                                   atol=3e-5)

    def test_quantization_error_small_vs_exact(self):
        b, h, s, d = 2, 4, 256, 64
        q = jnp.asarray(RNG.normal(size=(b, h, d)).astype(np.float32))
        k = jnp.asarray(RNG.normal(size=(b, h, s, d)).astype(np.float32))
        v = jnp.asarray(RNG.normal(size=(b, h, s, d)).astype(np.float32))
        kq, ks = kvq_ref.quantize_kv(k)
        vq, vs = kvq_ref.quantize_kv(v)
        bias = jnp.zeros((b, s))
        o_q = kvq_ref.decode_attention_ref(
            q.reshape(b, h, 1, d), kq, ks, vq, vs, bias, d ** -0.5)
        logits = jnp.einsum("bhd,bhsd->bhs", q, k) * d ** -0.5
        p = jax.nn.softmax(logits, -1)
        o_exact = jnp.einsum("bhs,bhsd->bhd", p, v)
        err = np.abs(np.asarray(o_q.reshape(b, h, d)) - np.asarray(o_exact))
        assert err.max() < 0.03  # int8 quantization noise bound

    def test_quantize_roundtrip_monotone(self):
        x = jnp.asarray(RNG.normal(size=(4, 16, 64)).astype(np.float32)) * 5
        q, s = kvq_ref.quantize_kv(x)
        err = np.abs(np.asarray(kvq_ref.dequantize_kv(q, s)) - np.asarray(x))
        assert err.max() <= np.abs(np.asarray(x)).max() / 127.0 * 0.51


# ---------------------------------------------------------------------------
# ssd: mamba2 chunk kernel
# ---------------------------------------------------------------------------
class TestSSDKernel:
    @pytest.mark.parametrize("b,L,h,p,n,q", [
        (1, 128, 2, 16, 32, 32),
        (2, 256, 3, 16, 32, 64),
        (2, 256, 4, 64, 128, 128),   # production-like dims
    ])
    def test_chunked_matches_sequential(self, b, L, h, p, n, q):
        x = jnp.asarray(RNG.normal(size=(b, L, h, p)).astype(np.float32))
        dt = jnp.asarray(RNG.uniform(0.001, 0.1, (b, L, h)).astype(np.float32))
        a = jnp.asarray(-RNG.uniform(0.5, 2.0, (h,)).astype(np.float32))
        bm = jnp.asarray(RNG.normal(size=(b, L, n)).astype(np.float32))
        cm = jnp.asarray(RNG.normal(size=(b, L, n)).astype(np.float32))
        d = jnp.asarray(RNG.normal(size=(h,)).astype(np.float32))
        y_seq = ssd_ref.ssd_scan_ref(x, dt, a, bm, cm, d)
        y_chunk = ssd_ops.ssd(x, dt, a, bm, cm, d, chunk=q, backend="ref")
        np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_seq),
                                   atol=5e-4, rtol=2e-3)

    def test_pallas_matches_ref(self):
        g, t, q, n, p = 4, 4, 64, 32, 16
        c = jnp.asarray(RNG.normal(size=(g, t, q, n)).astype(np.float32))
        b = jnp.asarray(RNG.normal(size=(g, t, q, n)).astype(np.float32))
        x = jnp.asarray(RNG.normal(size=(g, t, q, p)).astype(np.float32))
        acum = jnp.cumsum(
            jnp.asarray(-RNG.uniform(0.001, 0.2, (g, t, q)).astype(np.float32)),
            axis=-1)
        y_ref, st_ref = ssd_ref.ssd_chunk_ref(c, b, x, acum)
        y_k, st_k = ssd_kernel.ssd_chunk_pallas(c, b, x, acum, interpret=True)
        np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), atol=1e-5)
        np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_ref), atol=1e-5)

    def test_decode_step_matches_scan(self):
        b, L, h, p, n = 2, 16, 2, 8, 16
        x = jnp.asarray(RNG.normal(size=(b, L, h, p)).astype(np.float32))
        dt = jnp.asarray(RNG.uniform(0.01, 0.1, (b, L, h)).astype(np.float32))
        a = jnp.asarray(-RNG.uniform(0.5, 1.0, (h,)).astype(np.float32))
        bm = jnp.asarray(RNG.normal(size=(b, L, n)).astype(np.float32))
        cm = jnp.asarray(RNG.normal(size=(b, L, n)).astype(np.float32))
        d = jnp.zeros((h,))
        y_seq = ssd_ref.ssd_scan_ref(x, dt, a, bm, cm, d)
        state = jnp.zeros((b, h, n, p))
        for t in range(L):
            state, y_t = ssd_ops.ssd_decode_step(
                state, x[:, t], dt[:, t], a, bm[:, t], cm[:, t], d)
            np.testing.assert_allclose(np.asarray(y_t),
                                       np.asarray(y_seq[:, t]),
                                       atol=1e-4, rtol=1e-3)


# ---------------------------------------------------------------------------
# flash: prefill attention kernel
# ---------------------------------------------------------------------------
class TestFlashKernel:
    @pytest.mark.parametrize("b,h,hkv,s,d,window", [
        (1, 4, 4, 256, 64, 0),     # MHA causal
        (2, 8, 2, 256, 64, 0),     # GQA 4:1
        (1, 4, 2, 384, 32, 0),     # s % 128 via padding path
        (1, 4, 4, 256, 64, 64),    # sliding window
    ])
    def test_matches_ref(self, b, h, hkv, s, d, window):
        from repro.kernels.flash import ops as flash_ops
        q = jnp.asarray(RNG.normal(size=(b, h, s, d)).astype(np.float32))
        k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(np.float32))
        v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(np.float32))
        o_ref = flash_ops.flash_attention(q, k, v, window=window,
                                          backend="ref")
        o_int = flash_ops.flash_attention(q, k, v, window=window,
                                          backend="interpret")
        np.testing.assert_allclose(np.asarray(o_int), np.asarray(o_ref),
                                   atol=2e-5)
